"""Trip-count-aware HLO-text analyzer — the roofline's data source.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**, so
for scan-over-layers models it undercounts FLOPs/bytes/collectives by the
trip count (layers x grad-accum x seq-chunks). This analyzer re-derives
the totals from the compiled HLO text:

  * parses every computation into (name, shape, op, operands) tuples;
  * extracts while-loop trip counts from the condition computation
    (max integer constant compared against the induction variable —
    exact for lax.scan/fori_loop, an upper bound for dynamic
    while_loops);
  * walks the call graph (while x trip, fusion/call once, conditional
    max-of-branches) accumulating:
      - dot FLOPs: 2 * prod(result dims) * prod(contracted dims)
      - bytes accessed: operand + result bytes per effective instruction
      - collective operand bytes per op kind
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"\b(pred|s8|u8|s16|u16|bf16|f16|f8e4m3fn|f8e5m2|s32|u32|f32|s64"
    r"|u64|f64|c64|c128)\[([0-9,]*)\]")

_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_COND_BODY_RE = re.compile(
    r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_NO_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "after-all", "partition-id", "replica-id",
                 "iota", "copy-start", "copy-done"}

# ops an XLA:TPU fusion would keep in registers/VMEM (counted in the raw
# byte total but excluded from the fused-traffic estimate)
_ELEMENTWISE_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "tanh", "negate", "compare",
    "select", "convert", "broadcast", "reshape", "transpose", "reduce",
    "rsqrt", "sqrt", "power", "and", "or", "not", "xor", "log",
    "log-plus-one", "floor", "ceil", "clamp", "abs", "sign", "cosine",
    "sine", "is-finite", "reduce-window", "map", "slice", "rem",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "round-nearest-afz", "round-nearest-even", "logistic", "atan2",
}

_GROUPS_ARRAY_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,\s]+?)\}")


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    op: str
    rest: str          # operands + attributes (raw tail of the line)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for t, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[t]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


def parse_module(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4)))
    return comps


def _group_size(rest: str) -> int:
    m = _GROUPS_ARRAY_RE.search(rest)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes_accessed: float = 0.0      # every top-level instruction
    bytes_fused: float = 0.0         # TPU-fusion estimate (see below)
    collective_bytes: float = 0.0
    per_collective: Dict[str, Dict] = dataclasses.field(
        default_factory=lambda: defaultdict(
            lambda: {"count": 0.0, "bytes": 0.0}))
    bytes_by_op: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "Totals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes_accessed += other.bytes_accessed * mult
        self.bytes_fused += other.bytes_fused * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.per_collective.items():
            self.per_collective[k]["count"] += v["count"] * mult
            self.per_collective[k]["bytes"] += v["bytes"] * mult
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] += v * mult


class HloAnalyzer:
    def __init__(self, text: str, dynamic_while_default: int = 1):
        self.comps = parse_module(text)
        self.shapes: Dict[str, str] = {}
        for instrs in self.comps.values():
            for ins in instrs:
                self.shapes[ins.name] = ins.shape_str
        self._memo: Dict[str, Totals] = {}
        self._ew_memo: Dict[str, bool] = {}
        self.dynamic_while_default = dynamic_while_default
        self.while_trips: Dict[str, float] = {}

    def _non_ew_ops(self, comp: str) -> frozenset:
        """Non-elementwise opcodes inside a fused computation
        (transitively). Empty set => pure elementwise fusion."""
        if comp in self._ew_memo:
            return self._ew_memo[comp]
        self._ew_memo[comp] = frozenset()      # cycle guard
        out = set()
        for ins in self.comps.get(comp, []):
            if ins.op in _NO_BYTES_OPS or ins.op in _ELEMENTWISE_OPS:
                continue
            if ins.op == "fusion":
                cm = _CALLS_RE.search(ins.rest)
                if cm:
                    out |= self._non_ew_ops(cm.group(1))
                continue
            out.add(ins.op)
        self._ew_memo[comp] = frozenset(out)
        return self._ew_memo[comp]

    def _elementwise_only(self, comp: str) -> bool:
        return not self._non_ew_ops(comp)

    # ops whose HBM traffic is the *slice*, not the full buffer: a
    # dynamic-slice reads slice-many bytes from the big operand; an
    # in-place dynamic-update-slice writes update-many bytes. The scan
    # machinery (per-iteration weight slices from stacked arrays) is all
    # of this kind — counting full operands would overcount by the trip
    # count.
    _SLICE_LIKE = frozenset({"dynamic-slice", "dynamic-update-slice",
                             "copy", "pad"})

    def _slice_bytes(self, ins: Instr) -> float:
        """2 x the smallest participating tensor >= 1 KiB (the slice)."""
        sizes = [float(_shape_bytes(ins.shape_str))]
        operand_str = ins.rest.split(")", 1)[0]
        for name in _OPERAND_RE.findall(operand_str):
            sizes.append(float(_shape_bytes(self.shapes.get(name, ""))))
        big = [s for s in sizes if s >= 1024.0]
        return 2.0 * min(big) if big else sum(sizes)

    # -------------------------------------------------------- trip count
    def trip_count(self, cond_comp: str) -> float:
        instrs = self.comps.get(cond_comp, [])
        consts = []
        for ins in instrs:
            if ins.op == "constant":
                m = re.match(r"(\d+)\)", ins.rest)
                if m:
                    consts.append(int(m.group(1)))
            consts += [int(c) for c in _CONST_RE.findall(ins.rest)]
        big = [c for c in consts if c > 1]
        if big:
            return float(max(big))
        return float(self.dynamic_while_default)

    # ------------------------------------------------------ per-instr cost
    def _dot_flops(self, ins: Instr) -> float:
        result = 1.0
        for d in _shape_dims(ins.shape_str):
            result *= d
        lhs_name_m = _OPERAND_RE.search(ins.rest)
        contracted = 1.0
        if lhs_name_m:
            lhs_shape = self.shapes.get(lhs_name_m.group(1), "")
            dims = _shape_dims(lhs_shape)
            cm = _CONTRACT_RE.search(ins.rest)
            if cm and cm.group(1):
                for ci in cm.group(1).split(","):
                    i = int(ci)
                    if i < len(dims):
                        contracted *= dims[i]
        return 2.0 * result * contracted

    def _instr_bytes(self, ins: Instr) -> float:
        if ins.op in _NO_BYTES_OPS:
            return 0.0
        total = float(_shape_bytes(ins.shape_str))
        operand_str = ins.rest.split(")", 1)[0]
        for name in _OPERAND_RE.findall(operand_str):
            total += _shape_bytes(self.shapes.get(name, ""))
        return total

    def _collective(self, ins: Instr, t: Totals) -> None:
        op = ins.op.replace("-start", "")
        if op not in _COLLECTIVES:
            return
        if ins.op.endswith("-done"):
            return
        result = _shape_bytes(ins.shape_str)
        g = _group_size(ins.rest)
        if op == "all-gather":
            b = result // max(g, 1)
        elif op == "reduce-scatter":
            b = result * g
        else:
            b = result
        t.per_collective[op]["count"] += 1
        t.per_collective[op]["bytes"] += b
        t.collective_bytes += b

    # --------------------------------------------------------- traversal
    def analyze(self, comp: str) -> Totals:
        if comp in self._memo:
            return self._memo[comp]
        t = Totals()
        self._memo[comp] = t      # cycle guard (self-recursion impossible)
        for ins in self.comps.get(comp, []):
            if ins.op == "while":
                m = _COND_BODY_RE.search(ins.rest)
                if m:
                    trips = self.trip_count(m.group(1))
                    self.while_trips[ins.name] = trips
                    t.add(self.analyze(m.group(2)), trips)
                continue
            if ins.op in ("fusion", "call", "async-start"):
                # descend for flops/collectives; bytes count only at the
                # fusion boundary (the inner values are register/VMEM
                # resident on TPU, not HBM traffic)
                cm = _CALLS_RE.search(ins.rest)
                kinds = frozenset({"?"})
                if cm:
                    inner = self.analyze(cm.group(1))
                    t.flops += inner.flops
                    t.collective_bytes += inner.collective_bytes
                    for k, v in inner.per_collective.items():
                        t.per_collective[k]["count"] += v["count"]
                        t.per_collective[k]["bytes"] += v["bytes"]
                    kinds = self._non_ew_ops(cm.group(1))
                t.bytes_accessed += self._instr_bytes(ins)
                if kinds:
                    if kinds <= self._SLICE_LIKE:
                        b = self._slice_bytes(ins)
                        t.bytes_fused += b
                        t.bytes_by_op["slice-fusion"] += b
                    else:
                        b = self._instr_bytes(ins)
                        t.bytes_fused += b
                        t.bytes_by_op["fusion"] += b
                continue
            if ins.op == "conditional":
                branches = _OPERAND_RE.findall(
                    ins.rest.split("branch_computations=")[-1]) \
                    if "branch_computations=" in ins.rest else []
                sub = [self.analyze(b) for b in branches
                       if b in self.comps]
                if sub:
                    best = max(sub, key=lambda s: s.flops)
                    t.add(best)
                continue
            if ins.op == "dot":
                t.flops += self._dot_flops(ins)
            self._collective(ins, t)
            t.bytes_accessed += self._instr_bytes(ins)
            if ins.op not in _ELEMENTWISE_OPS:
                b = (self._slice_bytes(ins)
                     if ins.op in self._SLICE_LIKE
                     else self._instr_bytes(ins))
                t.bytes_fused += b
                t.bytes_by_op[ins.op] += b
        return t

    def entry(self) -> str:
        # entry computation is the one named main.* if present, else the
        # last computation in the module text
        for name in self.comps:
            if name.startswith("main"):
                return name
        return list(self.comps)[-1]

    def totals(self) -> Totals:
        return self.analyze(self.entry())


def analyze_hlo(text: str, dynamic_while_default: int = 1) -> Totals:
    return HloAnalyzer(text, dynamic_while_default).totals()


# ------------------------------------------------ legacy flat interfaces

def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, Dict]]:
    """Trip-count-aware total collective operand bytes."""
    t = analyze_hlo(hlo_text)
    per = {k: {"count": int(v["count"]), "bytes": int(v["bytes"])}
           for k, v in t.per_collective.items()}
    return int(t.collective_bytes), per


def collective_summary(hlo_text: str) -> str:
    total, per = collective_bytes(hlo_text)
    lines = [f"collective operand bytes: {total:,}"]
    for op, d in sorted(per.items()):
        lines.append(f"  {op:20s} x{d['count']:<6d} {d['bytes']:,} B")
    return "\n".join(lines)


def count_ops(hlo_text: str, opcode: str) -> int:
    return len(re.findall(rf"=\s*[^=]*?\b{re.escape(opcode)}\b", hlo_text))
