"""Logical-axis sharding rules (MaxText-style) -> GSPMD PartitionSpecs.

Model code annotates tensors with *logical* axis names; the launcher picks a
rule set mapping logical names to mesh axes. A dim is sharded only if its
size is divisible by the product of the mapped mesh axes — otherwise that
dim silently falls back to replication (e.g. gemma3's 4 heads on a 16-way
``model`` axis).

Rule sets:
  SINGLE_POD_RULES — mesh ("data", "model") = (16, 16)
    batch/fsdp -> data   (DP + ZeRO-style param/optimizer sharding)
    heads/ff/experts/vocab/inner -> model  (Megatron TP / EP)
    kv_seq -> model      (sequence-sharded KV cache for long-context decode)
  MULTI_POD_RULES  — mesh ("pod", "data", "model") = (2, 16, 16)
    batch/fsdp -> (pod, data); everything else as single-pod.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

AxisRules = Dict[str, Tuple[str, ...]]

SINGLE_POD_RULES: AxisRules = {
    "batch": ("data",),
    "fsdp": ("data",),            # weight dim sharded ZeRO-style
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "experts": ("model",),
    "inner": ("model",),          # mamba/rwkv inner width
    "kv_seq": ("model",),         # KV-cache sequence axis (decode SP)
    "seq": (),                    # activation sequence axis: replicated
    "embed": (),
    "head_dim": (),
    "state": (),
}

MULTI_POD_RULES: AxisRules = dict(
    SINGLE_POD_RULES,
    batch=("pod", "data"),
    fsdp=("pod", "data"),
)

# Serving-plane placement rules (DESIGN.md §7): the leading ``segment``
# axis of a stacked DeviceSegment tree shards one sub-segment (or
# replica) per ``model`` rank — the Fig. 1(b) segments <-> ranks
# layout ``make_search_step`` and the MeshQueryRouter fan out over —
# while the ``query`` batch axis rides ``data`` and everything else
# (block, vertex, neighbor dims) replicates within a rank's shard.
SEGMENT_SERVE_RULES: AxisRules = {
    "segment": ("model",),
    "query": ("data",),
    "block": (),
    "vertex": (),
    "dim": (),
}

_local = threading.local()


def set_rules(rules: Optional[AxisRules], mesh: Optional[Mesh]) -> None:
    _local.rules = rules
    _local.mesh = mesh


def current_rules() -> Tuple[Optional[AxisRules], Optional[Mesh]]:
    return getattr(_local, "rules", None), getattr(_local, "mesh", None)


@contextlib.contextmanager
def use_rules(rules: AxisRules, mesh: Mesh):
    prev = current_rules()
    set_rules(rules, mesh)
    try:
        with mesh:
            yield
    finally:
        set_rules(*prev)


def _mesh_axis_size(mesh: Mesh, axes: Sequence[str]) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def logical_spec(shape: Sequence[int], axes: Sequence[Optional[str]],
                 rules: AxisRules, mesh: Mesh) -> PartitionSpec:
    """Map logical axis names to a PartitionSpec, honouring divisibility."""
    assert len(shape) == len(axes), (shape, axes)
    spec = []
    used: set = set()
    for dim, name in zip(shape, axes):
        mesh_axes = rules.get(name, ()) if name else ()
        mesh_axes = tuple(a for a in mesh_axes if a not in used)
        if mesh_axes and dim % _mesh_axis_size(mesh, mesh_axes) == 0:
            used.update(mesh_axes)
            spec.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
        else:
            spec.append(None)
    return PartitionSpec(*spec)


def shard(x: jnp.ndarray, *axes: Optional[str]) -> jnp.ndarray:
    """with_sharding_constraint by logical names; no-op without rules."""
    rules, mesh = current_rules()
    if rules is None or mesh is None:
        return x
    spec = logical_spec(x.shape, axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_sharding_tree(spec_tree, rules: AxisRules, mesh: Mesh):
    """Map a tree of ``ParamSpec``-likes (``.shape``/``.axes``) to
    NamedShardings (used for jit in_shardings and checkpoint layouts)."""
    def one(ps):
        return NamedSharding(mesh,
                             logical_spec(ps.shape, ps.axes, rules, mesh))
    return jax.tree.map(one, spec_tree,
                        is_leaf=lambda x: hasattr(x, "axes"))


def spec_tree_to_shape_dtype(spec_tree, rules: AxisRules, mesh: Mesh,
                             dtype=None):
    """ParamSpec tree -> ShapeDtypeStruct tree with attached shardings
    (AOT lowering inputs: no allocation)."""
    def one(ps):
        sh = NamedSharding(mesh, logical_spec(ps.shape, ps.axes, rules, mesh))
        return jax.ShapeDtypeStruct(ps.shape, dtype or ps.dtype, sharding=sh)
    return jax.tree.map(one, spec_tree,
                        is_leaf=lambda x: hasattr(x, "axes"))
