"""Elastic re-mesh planning: map surviving node counts to a new mesh.

Policy: the ``model`` (TP) degree is pinned (weights are laid out for
it); elasticity comes from shrinking the ``data`` axis to the largest
power of two supported by the survivors, rescaling per-device batch to
keep the global batch constant, and raising grad-accum when the
per-device batch would not divide. Restart = restore latest checkpoint
with the new mesh (checkpoints are mesh-agnostic npz trees).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    data: int
    model: int
    pods: int
    per_device_batch: int
    grad_accum: int
    dropped_chips: int

    @property
    def chips(self) -> int:
        return self.pods * self.data * self.model


def plan_remesh(surviving_chips: int, model: int, global_batch: int,
                pods: int = 1, min_data: int = 1,
                base_grad_accum: int = 1) -> Optional[RemeshPlan]:
    """Largest (pod, data, model) mesh fitting the survivors; None if
    even the minimum mesh does not fit."""
    if surviving_chips < model * min_data * pods:
        if pods > 1:
            return plan_remesh(surviving_chips, model, global_batch,
                               pods=pods - 1, min_data=min_data,
                               base_grad_accum=base_grad_accum)
        return None
    data = 1
    while data * 2 * model * pods <= surviving_chips:
        data *= 2
    chips = data * model * pods
    dp_ways = data * pods
    accum = base_grad_accum
    while global_batch % (dp_ways * accum) and accum < global_batch:
        accum += 1
    per_dev = max(global_batch // (dp_ways * accum), 1)
    return RemeshPlan(data=data, model=model, pods=pods,
                      per_device_batch=per_dev, grad_accum=accum,
                      dropped_chips=surviving_chips - chips)
