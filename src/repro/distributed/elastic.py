"""Elastic re-mesh and segment-placement planning.

Re-mesh (``plan_remesh``): map surviving node counts to a new mesh.
Policy: the ``model`` (TP) degree is pinned (weights are laid out for
it); elasticity comes from shrinking the ``data`` axis to the largest
power of two supported by the survivors, rescaling per-device batch to
keep the global batch constant, and raising grad-accum when the
per-device batch would not divide. Restart = restore latest checkpoint
with the new mesh (checkpoints are mesh-agnostic npz trees).

Placement (``plan_placement`` / ``plan_rebalance``): the serving-plane
analogue — assign segment replicas to mesh ranks in proportion to
observed per-segment load, so the ``MeshQueryRouter`` can move
segments between ranks when the windowed per-rank ``IOStats`` fold
shows sustained skew (DESIGN.md §7). Planning is deterministic and
move-minimizing: ranks whose segment keeps quota under the new
proportions stay put, so a settled load re-plans to the identical
placement (zero moves — the rebalance-idempotence invariant)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    data: int
    model: int
    pods: int
    per_device_batch: int
    grad_accum: int
    dropped_chips: int

    @property
    def chips(self) -> int:
        return self.pods * self.data * self.model


def plan_remesh(surviving_chips: int, model: int, global_batch: int,
                pods: int = 1, min_data: int = 1,
                base_grad_accum: int = 1) -> Optional[RemeshPlan]:
    """Largest (pod, data, model) mesh fitting the survivors; None if
    even the minimum mesh does not fit."""
    if surviving_chips < model * min_data * pods:
        if pods > 1:
            return plan_remesh(surviving_chips, model, global_batch,
                               pods=pods - 1, min_data=min_data,
                               base_grad_accum=base_grad_accum)
        return None
    data = 1
    while data * 2 * model * pods <= surviving_chips:
        data *= 2
    chips = data * model * pods
    dp_ways = data * pods
    accum = base_grad_accum
    while global_batch % (dp_ways * accum) and accum < global_batch:
        accum += 1
    per_dev = max(global_batch // (dp_ways * accum), 1)
    return RemeshPlan(data=data, model=model, pods=pods,
                      per_device_batch=per_dev, grad_accum=accum,
                      dropped_chips=surviving_chips - chips)


# --------------------------------------------- serving segment placement

@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """A rank -> segment assignment plus the evidence it was planned
    from (returned by ``plan_rebalance``)."""
    placement: Tuple[int, ...]    # placement[rank] = segment index
    moves: Tuple[Tuple[int, int, int], ...]  # (rank, old_seg, new_seg)
    skew: float                   # max/mean rank load the plan saw
    seg_loads: Tuple[float, ...]  # per-segment load the quotas priced

    @property
    def fired(self) -> bool:
        return len(self.moves) > 0


def plan_placement(seg_loads: Sequence[float], ranks: int,
                   current: Optional[Sequence[int]] = None
                   ) -> List[int]:
    """Replica counts proportional to per-segment load, every segment
    on >= 1 rank (largest-remainder apportionment), materialized as a
    rank -> segment list.

    ``current`` makes the plan move-minimizing: every rank whose
    current segment still has quota under the new proportions keeps
    it; only surplus ranks are reassigned (in rank order, to the
    lowest-index segment short of quota). Deterministic, so planning
    twice from the same loads yields the identical placement — the
    idempotence the router's settled-stream invariant rests on."""
    s = len(seg_loads)
    if s == 0:
        raise ValueError("plan_placement needs at least one segment")
    if ranks < s:
        raise ValueError(
            f"{ranks} ranks cannot hold {s} segments at >= 1 replica "
            "each — shrink the segment set or grow the mesh")
    loads = [max(float(x), 0.0) for x in seg_loads]
    total = sum(loads)
    if total <= 0.0:
        loads = [1.0] * s                  # no signal: uniform replicas
        total = float(s)
    # every segment gets 1 guaranteed rank; the remaining ranks go by
    # largest remainder of the load-proportional quota
    extra = ranks - s
    quota = [ld / total * extra for ld in loads]
    counts = [1 + int(q) for q in quota]
    rem = sorted(range(s), key=lambda i: (-(quota[i] - int(quota[i])), i))
    short = ranks - sum(counts)
    for i in rem[:short]:
        counts[i] += 1
    if current is None:
        out: List[int] = []
        for i, c in enumerate(counts):
            out.extend([i] * c)
        return out
    # move-minimizing: keep ranks whose segment still has quota
    left = list(counts)
    keep = [-1] * ranks
    for r, seg in enumerate(current):
        if 0 <= seg < s and left[seg] > 0:
            keep[r] = seg
            left[seg] -= 1
    fill = [i for i, c in enumerate(left) for _ in range(c)]
    out = []
    j = 0
    for r in range(ranks):
        if keep[r] >= 0:
            out.append(keep[r])
        else:
            out.append(fill[j])
            j += 1
    return out


def plan_rebalance(current: Sequence[int], seg_loads: Sequence[float],
                   rank_loads: Sequence[float],
                   skew_threshold: float = 1.5) -> PlacementPlan:
    """One rebalance evaluation: re-plan placement from the windowed
    per-segment loads, gated on observed rank-load skew.

    Fires (non-empty ``moves``) only when max/mean ``rank_loads``
    reaches ``skew_threshold`` AND the move-minimizing re-plan differs
    from ``current`` — a balanced or already-proportional mesh plans
    zero moves, so applying the plan is idempotent."""
    ranks = len(current)
    active = [max(float(x), 0.0) for x in rank_loads]
    mean = sum(active) / max(len(active), 1)
    skew = (max(active) / mean) if mean > 0 else 0.0
    if skew < skew_threshold:
        return PlacementPlan(placement=tuple(current), moves=(),
                             skew=skew, seg_loads=tuple(seg_loads))
    new = plan_placement(seg_loads, ranks, current=current)
    moves = tuple((r, int(current[r]), int(new[r]))
                  for r in range(ranks) if new[r] != current[r])
    return PlacementPlan(placement=tuple(new), moves=moves, skew=skew,
                         seg_loads=tuple(seg_loads))
