"""Gradient compression: int8 quantized all-reduce with error feedback.

Per-leaf symmetric int8 quantization (per-tensor scale = max|g|/127);
the residual (g - dequant(q)) is carried in an error-feedback buffer and
added to the next step's gradient, making the compressed SGD unbiased in
the long run (Karimireddy et al., 2019). At 1000+ nodes this cuts the
gradient all-reduce bytes 4x (f32) / 2x (bf16) at negligible loss.

``compressed_psum`` is the collective-aware path used under shard_map /
pmap; ``quantize``/``dequantize`` + ``ErrorFeedback`` are pure-tensor
pieces unit-tested on CPU.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Tree = Any


def quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)).astype(jnp.float32) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_init(params: Tree) -> Tree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads: Tree, errors: Tree
                           ) -> Tuple[Tree, Tree, Tree]:
    """Returns (int8 tree, scales tree, new error tree)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize(corrected)
        new_e = corrected - dequantize(q, s)
        return q, s, new_e
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qs = jax.tree.unflatten(treedef, [o[0] for o in out])
    ss = jax.tree.unflatten(treedef, [o[1] for o in out])
    es = jax.tree.unflatten(treedef, [o[2] for o in out])
    return qs, ss, es


def compressed_psum(grads: Tree, errors: Tree, axis_name: str
                    ) -> Tuple[Tree, Tree]:
    """All-reduce int8 gradients across ``axis_name`` (inside shard_map).

    The scale is psum-maxed first so every rank dequantizes identically;
    int8 payloads are summed as int32 (no overflow up to 2^24 ranks).
    Returns (mean gradients f32, new error feedback)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        scale = jax.lax.pmax(jnp.max(jnp.abs(corrected)) / 127.0,
                             axis_name)
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(corrected / scale), -127, 127)
        new_e = corrected - q * scale
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return summed.astype(jnp.float32) * scale / n, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
