from repro.ft.checkpoint import CheckpointManager
from repro.ft.straggler import HeartbeatMonitor, StragglerReport
