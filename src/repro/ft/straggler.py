"""Heartbeat-based straggler/failure detection + elastic re-mesh plan.

Host-side control plane (unit-testable without a pod): workers report
step-completion heartbeats; the monitor flags nodes whose last beat is
older than ``timeout`` (dead) or whose step time exceeds
``straggler_factor`` x the fleet median (straggler). ``plan_remesh``
(distributed/elastic.py) converts the surviving-node count into a new
mesh and per-device batch that preserves the global batch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional


@dataclasses.dataclass
class StragglerReport:
    dead: List[int]
    stragglers: List[int]
    healthy: List[int]
    median_step_s: float


class HeartbeatMonitor:
    def __init__(self, num_nodes: int, timeout: float = 60.0,
                 straggler_factor: float = 2.0):
        self.num_nodes = num_nodes
        self.timeout = timeout
        self.factor = straggler_factor
        self.last_beat: Dict[int, float] = {}
        self.step_time: Dict[int, float] = {}

    def beat(self, node: int, step_s: float,
             now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        self.last_beat[node] = now
        self.step_time[node] = step_s

    def report(self, now: Optional[float] = None) -> StragglerReport:
        now = time.monotonic() if now is None else now
        dead, stragglers, healthy = [], [], []
        times = sorted(self.step_time.values())
        median = times[len(times) // 2] if times else 0.0
        for node in range(self.num_nodes):
            beat = self.last_beat.get(node)
            if beat is None or now - beat > self.timeout:
                dead.append(node)
            elif (median > 0
                  and self.step_time.get(node, 0.0) > self.factor * median):
                stragglers.append(node)
            else:
                healthy.append(node)
        return StragglerReport(dead=dead, stragglers=stragglers,
                               healthy=healthy, median_step_s=median)
