"""Step-granular checkpointing with atomic rename + retention.

Layout: <dir>/step_<N>/ {params.npz, opt.npz, meta.json}; a checkpoint
is visible only after the atomic directory rename, so a crash mid-save
never corrupts the latest restore point. ``keep`` most-recent steps are
retained. Restore resumes params, optimizer state and the exact data
pipeline position.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Tree = Any


def _flatten(tree: Tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(tree: Tree, flat: Dict[str, np.ndarray]) -> Tree:
    paths = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(paths[1], leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, params: Tree, opt_state: Tree,
             pipeline_state: Dict) -> str:
        final = self._step_dir(step)
        tmp = tempfile.mkdtemp(dir=self.dir,
                               prefix=f"step_{step:08d}.tmp.")
        np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
        np.savez(os.path.join(tmp, "opt.npz"), **_flatten(opt_state))
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "pipeline": pipeline_state}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def restore(self, params_like: Tree, opt_like: Tree,
                step: Optional[int] = None
                ) -> Tuple[Tree, Tree, Dict, int]:
        step = step if step is not None else self.latest_step()
        assert step is not None, "no checkpoint found"
        d = self._step_dir(step)
        pz = dict(np.load(os.path.join(d, "params.npz")))
        oz = dict(np.load(os.path.join(d, "opt.npz")))
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        return (_unflatten_into(params_like, pz),
                _unflatten_into(opt_like, oz),
                meta["pipeline"], meta["step"])
