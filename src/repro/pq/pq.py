"""Product quantization (Jégou et al. [36]) for memory-resident routing.

Starling (like DiskANN) keeps PQ short codes of *all* vectors in memory and
ranks the candidate queue by asymmetric-distance computation (ADC), saving
full-precision disk reads (§5.1 "PQ-based approximate distance").

Pipeline:
  train_pq   — per-subspace Lloyd k-means (jit'd) on a training sample
  encode_pq  — [N, M] uint8 codes
  adc_lut    — per-query [M, K] lookup table of subspace distances
  adc_distance — sum LUT entries along codes (the Pallas kernel
                 ``repro.kernels.pq_adc`` is the TPU version of this)
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import PQParams


@dataclasses.dataclass
class PQCodebook:
    centroids: np.ndarray     # [M, K, dsub] float32
    dim: int
    metric: str = "l2"

    @property
    def num_subspaces(self) -> int:
        return self.centroids.shape[0]

    @property
    def num_centroids(self) -> int:
        return self.centroids.shape[1]

    @property
    def dsub(self) -> int:
        return self.centroids.shape[2]

    def memory_bytes(self) -> int:
        return self.centroids.nbytes


@functools.partial(jax.jit, static_argnames=("iters",))
def _lloyd(x: jnp.ndarray, init: jnp.ndarray, iters: int) -> jnp.ndarray:
    """x [N, d], init [K, d] -> [K, d]. Empty clusters keep their centroid."""
    def step(cent, _):
        d = (jnp.sum(x * x, 1, keepdims=True) + jnp.sum(cent * cent, 1)
             - 2.0 * x @ cent.T)
        a = jnp.argmin(d, axis=1)
        one = jax.nn.one_hot(a, cent.shape[0], dtype=x.dtype)   # [N, K]
        cnt = one.sum(0)
        tot = one.T @ x
        new = jnp.where(cnt[:, None] > 0, tot / jnp.maximum(cnt[:, None], 1),
                        cent)
        return new, None
    cent, _ = jax.lax.scan(step, init, None, length=iters)
    return cent


def train_pq(x: np.ndarray, p: PQParams, metric: str = "l2") -> PQCodebook:
    n, d = x.shape
    m = p.num_subspaces
    assert d % m == 0, f"dim {d} not divisible by M={m}"
    dsub = d // m
    k = min(p.num_centroids, n)
    rng = np.random.default_rng(p.seed)
    sample = x[rng.choice(n, size=min(p.train_sample, n), replace=False)]
    cent = np.empty((m, p.num_centroids, dsub), np.float32)
    for j in range(m):
        sub = sample[:, j * dsub:(j + 1) * dsub].astype(np.float32)
        init = sub[rng.choice(sub.shape[0], size=k, replace=False)]
        c = np.asarray(_lloyd(jnp.asarray(sub), jnp.asarray(init),
                              p.train_iters))
        if k < p.num_centroids:   # tiny datasets: tile to K
            reps = -(-p.num_centroids // k)
            c = np.tile(c, (reps, 1))[: p.num_centroids]
        cent[j] = c
    return PQCodebook(centroids=cent, dim=d, metric=metric)


@jax.jit
def _encode(x: jnp.ndarray, cent: jnp.ndarray) -> jnp.ndarray:
    """x [N, M, dsub], cent [M, K, dsub] -> codes [N, M] uint8."""
    d = (jnp.sum(x * x, -1)[:, :, None]
         + jnp.sum(cent * cent, -1)[None]
         - 2.0 * jnp.einsum("nmd,mkd->nmk", x, cent))
    return jnp.argmin(d, axis=-1).astype(jnp.uint8)


def encode_pq(x: np.ndarray, cb: PQCodebook, chunk: int = 65536
              ) -> np.ndarray:
    n, d = x.shape
    m, dsub = cb.num_subspaces, cb.dsub
    out = np.empty((n, m), np.uint8)
    cent = jnp.asarray(cb.centroids)
    for s in range(0, n, chunk):
        xs = x[s:s + chunk].astype(np.float32).reshape(-1, m, dsub)
        out[s:s + chunk] = np.asarray(_encode(jnp.asarray(xs), cent))
    return out


def adc_lut(q: np.ndarray, cb: PQCodebook) -> np.ndarray:
    """Query LUT [M, K]: subspace distance from q's sub-vector to each
    centroid. For IP the LUT holds negated partial inner products so that
    summation stays 'smaller is better'."""
    m, k, dsub = cb.centroids.shape
    qs = q.astype(np.float32).reshape(m, 1, dsub)
    if cb.metric == "ip":
        return -(cb.centroids * qs).sum(-1)
    diff = cb.centroids - qs
    return np.einsum("mkd,mkd->mk", diff, diff)


def adc_lut_batch(q: np.ndarray, cb: PQCodebook) -> np.ndarray:
    """[Q, D] -> [Q, M, K]."""
    m, k, dsub = cb.centroids.shape
    qs = q.astype(np.float32).reshape(q.shape[0], m, 1, dsub)
    if cb.metric == "ip":
        return -(cb.centroids[None] * qs).sum(-1)
    diff = cb.centroids[None] - qs
    return np.einsum("qmkd,qmkd->qmk", diff, diff)


def adc_distance(lut: np.ndarray, codes: np.ndarray) -> np.ndarray:
    """lut [M, K], codes [n, M] -> [n] approximate distances (numpy ref)."""
    m = lut.shape[0]
    return lut[np.arange(m)[None, :], codes.astype(np.int64)].sum(axis=1)


def reconstruct(codes: np.ndarray, cb: PQCodebook) -> np.ndarray:
    """Decode codes back to vectors (for error bounds in tests)."""
    m, _, dsub = cb.centroids.shape
    parts = [cb.centroids[j, codes[:, j].astype(np.int64)]
             for j in range(m)]
    return np.concatenate(parts, axis=1)
