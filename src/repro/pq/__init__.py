from repro.pq.pq import (PQCodebook, train_pq, encode_pq, adc_lut,
                         adc_lut_batch, adc_distance, reconstruct)
